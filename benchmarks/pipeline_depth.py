"""Per-layer vs segment-compiled execution (ISSUE 4 tentpole, DESIGN.md §9).

Two arms per coding scheme on the same network and worker fleet:

* **per_layer** — the paper's pipeline: every type-1 conv is an isolated
  split -> encode -> dispatch -> decode round trip (``compile_plan`` with
  ``max_depth=1``);
* **segment**  — the netplan compiler's coded segments: one encode at
  entry, resident worker chains with composed halos, one decode at exit,
  cut points placed by the latency DP.

Reported per arm: encode/decode boundary-op count (2 x segments — also
*counted* on the executed run, not just promised), master<->worker
transfer bytes, the analytic segment-model latency, and an **executed**
end-to-end latency: the real forward runs piece-by-piece on the threaded
worker pool (FakeClock virtual time, shift-exponential chain round-trips
at the paper-testbed parameters), decoding each segment at the k-th
arrival.  MDS cannot fuse across relu (linear mixes do not commute with
activations), so its two arms coincide on relu networks — the honest
negative result; the selection schemes (replication/uncoded) are where
the network-level view pays.

Full mode compiles VGG16 at 224 (analytic) and executes VGG16 at 64;
``--quick`` executes the small CNN only (CI).  Writes
BENCH_pipeline.json / BENCH_pipeline_quick.json.

Run: PYTHONPATH=src python -m benchmarks.pipeline_depth [--quick]
"""
from __future__ import annotations

import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coded_conv import boundary_op_counter, conv2d, run_segment
from repro.core.latency import SystemParams
from repro.core.netplan import (LocalStep, NetPlan, SegmentStep, compile_plan,
                                segment_layer_sizes, segment_sizes)
from repro.dist import CodedExecutor, FakeClock, SegmentDelay
from repro.models.cnn import (_finish_layer, _pad_hw, init_cnn,
                              small_cnn_layers, vgg16_conv_specs)

from .common import PAPER_PARAMS, Csv

SCHEMES = ("mds", "replication", "uncoded")
N_WORKERS = 10

# The small CNN's layers are only a few MFLOP: on the paper's WiFi-scale
# testbed they are all type-2 (nothing distributes), and on a fast LAN
# the regime is compute-bound (fusion saves little).  The honest window
# in between — an edge CPU on a ~200 Mbps LAN, cost ratio 6.0 so the
# derived type-1 threshold (8.4 FLOP/B) admits all four layers — is
# where the quick arm can exercise both stories at once: fewer boundary
# ops AND a (small) latency win.  VGG16 carries the headline numbers.
QUICK_SMALL_PARAMS = SystemParams(
    mu_m=5e9, theta_m=2e-10, mu_cmp=2e8, theta_cmp=2e-9,
    mu_rec=1.25e8, theta_rec=3.4e-8, mu_sen=1.25e8, theta_sen=3.4e-8)


def executed_latency(plan: NetPlan, convs, x, params, n_workers: int,
                     seed: int, streamed: bool = False
                     ) -> tuple[float, dict, "np.ndarray"]:
    """Walk the plan on a FakeClock worker pool; return (virtual end-to-end
    seconds, counted boundary ops, final activations).  Master
    encode/decode ride on top at their mean durations; local steps at the
    master's compute rate.  ``streamed`` ships each segment's entry/exit
    in ``SegmentStep.chunks`` column chunks (DESIGN.md §11): the SAME rng
    world, but each piece's round trip is the pipelined chunk timeline
    instead of the serial stage sum — and the decoded output must be
    bit-identical."""
    total = 0.0
    with CodedExecutor(n_workers, clock=FakeClock(), timeout_s=600.0) as ex, \
            boundary_op_counter() as ops:
        h = x
        for step in plan.steps:
            sub = plan.layers[step.start:step.stop]
            ws = [convs[i] for i in range(step.start, step.stop)]
            if isinstance(step, SegmentStep):
                specs = [li.spec for li in sub]
                pads = [li.pad for li in sub]
                chunks = step.chunks if streamed else 1
                lsz = segment_layer_sizes(specs, pads, step.scheme,
                                          step.split)
                ex.pool.delay_model = SegmentDelay(params, lsz,
                                                   seed=seed + step.start,
                                                   chunks=chunks)
                y = run_segment(_pad_hw(h, sub[0].pad), ws, step.scheme,
                                specs, pads, [li.act for li in sub],
                                split=step.split, executor=ex,
                                stream_chunks=chunks)
                sizes, _ = segment_sizes(specs, pads, step.scheme, step.split)
                total += (sizes.n_enc + sizes.n_dec) * (1.0 / params.mu_m
                                                        + params.theta_m)
                total += ex.last_report.t_complete
                h = _finish_layer(y, sub[-1])
            else:
                for li, w in zip(sub, ws):
                    h = _finish_layer(conv2d(_pad_hw(h, li.pad), w,
                                             li.spec.stride), li)
                total += step.est_latency_s
        return total, dict(ops), np.asarray(h)


def executed_mean(plan, convs, x, params, n_workers, seeds=(0, 1000, 2000)
                  ) -> tuple[float, dict]:
    """Average the executed virtual latency over a few delay seeds (one
    k-th-arrival draw per segment per seed) — the committed numbers must
    not ride a single lucky sample."""
    lats, ops = [], None
    for s in seeds:
        lat, ops, _ = executed_latency(plan, convs, x, params, n_workers, s)
        lats.append(lat)
    return float(np.mean(lats)), ops


def stream_compare(plan: NetPlan, convs, x, params, n_workers: int,
                   seeds=(0, 1000, 2000)) -> dict:
    """Streamed vs unstreamed execution of the SAME segment plan, per delay
    seed.  Per-seed the comparison is exact: the rng world is shared, every
    sub-stage draw identical, and the pipelined chunk timeline is
    componentwise <= the serial stage sum, so the k-th-arrival completion
    cannot grow — the acceptance asserts it per seed, plus bit-identical
    decoded outputs."""
    rows, identical, close = [], True, True
    for s in seeds:
        lat_u, _, h_u = executed_latency(plan, convs, x, params, n_workers, s)
        lat_s, _, h_s = executed_latency(plan, convs, x, params, n_workers, s,
                                         streamed=True)
        rows.append({"seed": s, "unstreamed_s": lat_u, "streamed_s": lat_s})
        identical = identical and bool(np.array_equal(h_u, h_s))
        # chunked piece times can reorder the k-th arrival, so a linear-mix
        # scheme may decode from a DIFFERENT subset: mathematically equal,
        # numerically a different decode matrix.  Selection schemes pick
        # exact copies, so they must stay bitwise identical regardless;
        # same-subset chunked decode is bitwise (tests/test_stream_exec.py).
        scale = float(np.max(np.abs(h_u))) or 1.0
        close = close and bool(np.max(np.abs(h_u - h_s)) <= 1e-2 * scale)
    mean_u = float(np.mean([r["unstreamed_s"] for r in rows]))
    mean_s = float(np.mean([r["streamed_s"] for r in rows]))
    return {
        "chunks": [s.chunks for s in plan.segments],
        "per_seed": rows,
        "unstreamed_mean_s": mean_u,
        "streamed_mean_s": mean_s,
        "reduction": 1.0 - mean_s / mean_u if mean_u else 0.0,
        "never_worse": all(r["streamed_s"] <= r["unstreamed_s"] + 1e-12
                           for r in rows),
        "outputs_identical": identical,
        "outputs_close": close,
    }


def _arm_stats(plan: NetPlan) -> dict:
    return {
        "segments": plan.n_segments,
        "boundary_coding_ops": plan.boundary_coding_ops,
        "depths": [s.depth for s in plan.segments],
        "ks": [s.k for s in plan.segments],
        "master_worker_bytes": plan.master_worker_bytes,
        "halo_extra_bytes": int(sum(s.halo_extra_bytes
                                    for s in plan.segments)),
        "latency_model_s": plan.est_latency_s,
    }


def compare(layers, convs, x, params, n_workers: int, scheme: str,
            execute: bool, seed: int = 0) -> dict:
    seg = compile_plan(layers, n_workers, params, scheme)
    per = compile_plan(layers, n_workers, params, scheme, max_depth=1)
    out = {"segment": _arm_stats(seg), "per_layer": _arm_stats(per)}
    if execute:
        for arm, plan in (("segment", seg), ("per_layer", per)):
            lat, ops = executed_mean(plan, convs, x, params, n_workers)
            assert ops["encode"] == plan.n_segments, (ops, plan.n_segments)
            assert ops["decode"] == plan.n_segments, (ops, plan.n_segments)
            out[arm]["latency_executed_s"] = lat
            out[arm]["counted_boundary_ops"] = ops["encode"] + ops["decode"]
    out["model_reduction"] = 1.0 - (out["segment"]["latency_model_s"]
                                    / out["per_layer"]["latency_model_s"])
    if execute:
        out["executed_reduction"] = (
            1.0 - out["segment"]["latency_executed_s"]
            / out["per_layer"]["latency_executed_s"])
    return out


def run(csv: Csv, quick: bool = False) -> dict:
    out = {"n_workers": N_WORKERS, "networks": {}}

    # (name, layers, image, params, execute)
    if quick:
        nets = [("small_cnn@32", small_cnn_layers(32, QUICK_SMALL_PARAMS),
                 32, QUICK_SMALL_PARAMS, True)]
        featured = "small_cnn@32"
    else:
        nets = [("small_cnn@32", small_cnn_layers(32, QUICK_SMALL_PARAMS),
                 32, QUICK_SMALL_PARAMS, True),
                ("vgg16@224", vgg16_conv_specs(224, PAPER_PARAMS), 224,
                 PAPER_PARAMS, True)]
        featured = "vgg16@224"

    for name, layers, image, params, execute in nets:
        entry = {}
        convs = None
        x = None
        if execute:
            p = init_cnn(jax.random.PRNGKey(0), layers)
            convs = p["convs"]
            x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, image, image),
                                  jnp.float32)
        for scheme in SCHEMES:
            entry[scheme] = compare(layers, convs, x, params, N_WORKERS,
                                    scheme, execute)
            if execute and name == "small_cnn@32":
                # streamed scatter/gather on the segment plan (§11): same
                # rng world, pipelined chunk timelines, identical outputs
                seg_plan = compile_plan(layers, N_WORKERS, params, scheme)
                entry[scheme]["streaming"] = stream_compare(
                    seg_plan, convs, x, params, N_WORKERS)
        out["networks"][name] = entry

    # acceptance: the segment compiler never loses, and the fused
    # (selection-scheme) pipelines win outright where fusion is legal
    feat = out["networks"][featured]
    out["acceptance"] = {
        "featured": featured,
        "replication_executed_reduction":
            feat["replication"]["executed_reduction"],
        "uncoded_executed_reduction": feat["uncoded"]["executed_reduction"],
        "mds_model_regression": feat["mds"]["model_reduction"],
        "small_cnn_never_worse": (
            out["networks"]["small_cnn@32"]["replication"]["model_reduction"]
            >= 0.0),
    }
    # streamed scatter/gather (§11): per-seed exact — same rng world,
    # pipelined chunk timeline <= serial stage sum — and bit-identical
    small = out["networks"]["small_cnn@32"]
    out["acceptance"].update({
        "streamed_never_worse": all(
            small[s]["streaming"]["never_worse"] for s in SCHEMES),
        # selection schemes decode exact copies: bitwise, whatever subset
        # wins the k-th arrival; linear mixes may decode from a different
        # subset under chunked timing, so they pin closeness instead
        "streamed_outputs_identical": all(
            small[s]["streaming"]["outputs_identical"]
            for s in ("replication", "uncoded")),
        "streamed_outputs_close": all(
            small[s]["streaming"]["outputs_close"] for s in SCHEMES),
        "streamed_reduction_replication":
            small["replication"]["streaming"]["reduction"],
    })
    csv.add("pipeline_streamed_reduction_replication",
            small["replication"]["streaming"]["reduction"] * 100.0,
            "percent virtual latency saved by streamed scatter/gather "
            "(small_cnn@32, replication)")
    for scheme in SCHEMES:
        st = small[scheme]["streaming"]
        print(f"small_cnn@32 {scheme} streamed: "
              f"{st['unstreamed_mean_s']:.4f}s -> {st['streamed_mean_s']:.4f}s "
              f"({st['reduction']:+.1%}, chunks={st['chunks']}, "
              f"identical={st['outputs_identical']})")
    for scheme in ("replication", "uncoded"):
        csv.add(f"pipeline_{scheme}_executed_reduction",
                feat[scheme]["executed_reduction"] * 100.0,
                f"percent executed latency saved, segment vs per-layer "
                f"({featured})")
        print(f"{featured} {scheme}: per-layer "
              f"{feat[scheme]['per_layer']['latency_executed_s']:.3f}s "
              f"({feat[scheme]['per_layer']['boundary_coding_ops']} ops) -> "
              f"segment {feat[scheme]['segment']['latency_executed_s']:.3f}s "
              f"({feat[scheme]['segment']['boundary_coding_ops']} ops), "
              f"{feat[scheme]['executed_reduction']:+.1%}")
    name = "BENCH_pipeline_quick.json" if quick else "BENCH_pipeline.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path.name}")
    return out


if __name__ == "__main__":
    run(Csv(), quick="--quick" in sys.argv[1:])
