"""Wall-clock coded-vs-uncoded on the live worker pool (ISSUE 2 satellite).

Measures — on real threaded execution, RealClock, modeled per-piece delays
— how much the k-of-n early exit saves when one of n workers straggles.
This is the executed counterpart of the fig5/fig6 simulations: completion
really happens at the k-th arrival and the straggler really gets cancelled
mid-sleep.

Writes BENCH_pool.json at the repo root and emits the benchmark CSV
contract.  Target: coded wall-clock beats uncoded by >= 30% under a 10x
straggler (the paper reports up to 34.2% overall; here the layer is
transmission-light so the exec-phase saving dominates).

Run: PYTHONPATH=src python -m benchmarks.pool_wallclock
"""
from __future__ import annotations

import json
import pathlib

import numpy as np
import jax.numpy as jnp

from repro.core.coded_conv import coded_conv2d, conv2d
from repro.core.schemes import get_scheme
from repro.core.splitting import ConvSpec
from repro.dist import CodedExecutor, DeterministicDelay, FaultPlan, RealClock

from .common import Csv

N, K = 5, 3
PIECE_S = 0.02   # modeled healthy per-piece round-trip
STRAGGLE = 10.0  # one worker 10x slower (paper §V scenario 3)
REPS = 5


def _measure(scheme, reps=REPS):
    spec = ConvSpec(c_in=8, c_out=8, h_in=16, w_in=26, kernel=3, batch=2)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 16, 26)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 8, 3, 3)), jnp.float32)
    y_ref = np.asarray(conv2d(x, w, 1))
    walls = []
    with CodedExecutor(N, clock=RealClock(),
                       delay_model=DeterministicDelay(PIECE_S),
                       fault_plan=FaultPlan(straggler={0: STRAGGLE})) as ex:
        # warmup run compiles the per-thread conv executables
        coded_conv2d(x, w, scheme, spec, executor=ex)
        for _ in range(reps):
            y = coded_conv2d(x, w, scheme, spec, executor=ex)
            walls.append(ex.last_report.wall_s)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
    return float(np.mean(walls)), float(np.std(walls))


def run(csv: Csv) -> dict:
    coded_mean, coded_std = _measure(get_scheme("mds").make(N, K))
    unc_mean, unc_std = _measure(get_scheme("uncoded").make(N))
    reduction = 1.0 - coded_mean / unc_mean
    csv.add("pool_wallclock_coded", coded_mean * 1e6,
            f"mds({N},{K}) straggler{STRAGGLE:g}x")
    csv.add("pool_wallclock_uncoded", unc_mean * 1e6,
            f"n={N} straggler{STRAGGLE:g}x")
    csv.add("pool_wallclock_reduction", reduction * 100.0,
            "percent latency saved by k-of-n early exit")
    out = {
        "workload": "one coded conv layer on the live WorkerPool",
        "n": N,
        "k": K,
        "piece_s": PIECE_S,
        "straggler_mult": STRAGGLE,
        "reps": REPS,
        "coded_wall_s": coded_mean,
        "coded_wall_std_s": coded_std,
        "uncoded_wall_s": unc_mean,
        "uncoded_wall_std_s": unc_std,
        "reduction": reduction,
        "target_reduction": 0.30,
    }
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_pool.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"coded {coded_mean * 1e3:.1f} ms vs uncoded {unc_mean * 1e3:.1f} ms"
          f" -> {reduction:+.1%} (wrote {path.name})")
    return out


if __name__ == "__main__":
    run(Csv())
