"""Fig. 4: encoding/decoding overhead per convolutional layer.

For each type-1 layer at k = k°, reports the master-side enc+dec share of
the layer's total expected latency.  The paper measures 2-9%.
"""
from __future__ import annotations

from repro.core.latency import phase_sizes
from repro.core.planner import L, k_circ

from .common import Csv, N_WORKERS, PAPER_PARAMS, type1_layers


def run(csv: Csv):
    for net in ("vgg16", "resnet18"):
        shares = []
        for li in type1_layers(net):
            k = k_circ(li.spec, N_WORKERS, PAPER_PARAMS)
            s = phase_sizes(li.spec, N_WORKERS, k)
            encdec = (s.n_enc + s.n_dec) * (1.0 / PAPER_PARAMS.mu_m
                                            + PAPER_PARAMS.theta_m)
            total = L(li.spec, N_WORKERS, k, PAPER_PARAMS)
            shares.append(encdec / total)
        csv.add(f"fig4/{net}/encdec_share",
                1e6 * sum(shares) / len(shares),
                f"min={min(shares):.3f};max={max(shares):.3f};"
                f"mean={sum(shares) / len(shares):.3f}")


if __name__ == "__main__":
    run(Csv())
