"""Elastic serving under churn: rateless LT + autoscaler vs a static
fixed-n fleet on the same departure trace (ISSUE 7; DESIGN.md §12).

Scenario: the serving-under-load testbed (tiny transformer, Poisson
open-loop traffic, shift-exp piece round-trips on a virtual-clock pool)
hit by a scripted membership trace instead of a straggler:

* a **flash crowd** commissions 2 fresh workers at t=FLASH_T (capacity
  arriving ahead of an expected spike);
* a **rolling restart** takes base workers 1 and 2 down at staggered
  times — a restarted device loses its resident state, so each restart
  is a permanent departure plus (for the elastic system only) a
  replacement join ``DOWN_S`` later.

Both arms see the *same departure process*; what differs is whether the
system can absorb commissioned capacity:

* **elastic_lt** — ``CodedExecutor(elastic=True)`` with the rateless LT
  scheme: n follows the live fleet before every coded GEMM (k° fixed —
  joiners mean more coded rows, never a re-encode of resident pieces),
  the full churn trace applies (departures AND joins), and a queue-driven
  :class:`~repro.dist.Autoscaler` adds headroom if the backlog ever costs
  more than a worker;
* **fixed_mds** — the static fleet: mds(4,3), no elasticity, no
  autoscaler, and only the departure events of the same trace (a static
  deployment has nobody to commission replacements).  After both
  restarts the 4 pieces of every GEMM round-robin onto the 2 survivors —
  two pieces deep per worker, so the k-th (3rd) arrival waits for a
  second-position piece: ~2x per-GEMM latency, and the queue diverges at
  matched offered load.

Headline (BENCH_elastic.json acceptance): post-churn the elastic arm
holds deadline attainment within 10% of its pre-churn level, while the
fixed-n arm loses at least 2x — per-epoch goodput shows WHERE the static
fleet collapses and the membership timeline shows why the elastic one
does not.

Run: PYTHONPATH=src python -m benchmarks.elastic_churn [--quick]
"""
from __future__ import annotations

import json
import pathlib
import sys

from repro.dist import Autoscaler, ChurnSchedule, CodedExecutor, FakeClock
from repro.serving import (Engine, LengthDist, PoissonArrivals,
                           ServingScheduler, Workload, summarize)

from .common import Csv
from .serving_load import (K_MDS, MASTER_CALL_S, MAX_BATCH, MAX_NEW,
                           N_PIECES, N_WORKERS, PIECE_S, PROMPTS, VOCAB,
                           _cfg, serve_delay)

FLASH_T = 0.6         # flash crowd: 2 fresh workers commissioned just
                      # ahead of the maintenance window, operator-style
                      # (any earlier and the autoscaler rightly drains
                      # the idle capacity before the restarts land)
RESTART_T0 = 0.7      # rolling restart of base workers 1, 2 starts here
STAGGER_S = 0.15      # consecutive restarts start this far apart
DOWN_S = 0.25         # replacement joins this long after each departure
RESTART_WORKERS = (1, 2)
DEADLINE_S = 100 * PIECE_S  # e2e SLO (arrival -> last token)
RATE = 26.0           # offered req/s: under capacity at 4 workers,
                      # over HALF capacity — a 2-worker fleet diverges
EPOCH_S = 0.25        # per-epoch goodput bucket width
EPS = 1e-9


def churn_trace() -> ChurnSchedule:
    """The full elastic-system trace: flash-crowd joins + rolling restart
    (each departure followed by a commissioned replacement)."""
    return (ChurnSchedule.flash_crowd(FLASH_T, 2)
            + ChurnSchedule.rolling_restart(RESTART_WORKERS, RESTART_T0,
                                            down_s=DOWN_S,
                                            stagger_s=STAGGER_S))


def static_projection(trace: ChurnSchedule) -> ChurnSchedule:
    """What a static fleet experiences: the departures of ``trace``, none
    of its joins — a fixed-n deployment has nobody commissioning
    replacements, so restarted workers simply never come back."""
    return ChurnSchedule(tuple(e for e in trace.events
                               if e.action == "remove"))


def run_arm(requests, scheme: str, k: int, *, elastic: bool,
            trace: ChurnSchedule, autoscale: bool, max_seq: int,
            seed: int = 0):
    """One serving run over ``trace`` on a fresh 4-worker pool."""
    with CodedExecutor(N_WORKERS, clock=FakeClock(),
                       delay_model=serve_delay(k, seed),
                       timeout_s=600.0, elastic=elastic) as ex:
        auto = (Autoscaler(ex.pool, min_workers=N_WORKERS, max_workers=8,
                           target_queue=1.0, alpha=0.7, cooldown_steps=3)
                if autoscale else None)
        eng = Engine(_cfg(scheme, k), seed=0, executor=ex)
        sched = ServingScheduler(eng, max_seq=max_seq, max_batch=MAX_BATCH,
                                 master_call_s=MASTER_CALL_S,
                                 delay_seed_stride=1, churn=trace,
                                 autoscaler=auto)
        return sched.serve(requests)


def _attainment(records, deadline_s: float) -> float | None:
    if not records:
        return None
    return sum(1 for r in records if r.e2e_s <= deadline_s) / len(records)


def split_attainment(result, t_split: float, deadline_s: float) -> dict:
    """Deadline attainment for requests arriving before vs from
    ``t_split`` (the first departure): the post-churn cohort is the one
    that lives on the degraded fleet."""
    pre = [r for r in result.records if r.arrival_s < t_split]
    post = [r for r in result.records if r.arrival_s >= t_split]
    return {
        "pre_requests": len(pre),
        "post_requests": len(post),
        "pre_attainment": _attainment(pre, deadline_s),
        "post_attainment": _attainment(post, deadline_s),
    }


def _arm_summary(result, rate: float) -> dict:
    s = summarize(result, deadline_s=DEADLINE_S, epoch_s=EPOCH_S)
    s.pop("queue_timeline", None)  # bulky; epochs carry the timeline story
    s["offered_rps"] = rate
    s["cohorts"] = split_attainment(result, RESTART_T0, DEADLINE_S)
    return s


def run(csv: Csv, quick: bool = False) -> dict:
    n_requests = 40 if quick else 72
    rate = RATE
    max_seq = max(PROMPTS) + max(MAX_NEW)
    wl = Workload(PoissonArrivals(rate), LengthDist(PROMPTS),
                  LengthDist(MAX_NEW), vocab=VOCAB, seed=11)
    reqs = wl.generate(n_requests)
    trace = churn_trace()
    out: dict = {
        "workload": "Poisson open-loop, tiny transformer, 4-worker virtual "
                    "pool; flash crowd (+2 workers) at "
                    f"t={FLASH_T:g}s, rolling restart of workers "
                    f"{list(RESTART_WORKERS)} from t={RESTART_T0:g}s "
                    f"(stagger {STAGGER_S:g}s, replacement after "
                    f"{DOWN_S:g}s)",
        "n_requests": n_requests, "offered_rps": rate,
        "deadline_s": DEADLINE_S, "epoch_s": EPOCH_S,
        "churn": [[e.t, e.action, e.worker] for e in trace.events],
        "arms": {},
    }
    res_e = run_arm(reqs, "lt", K_MDS, elastic=True, trace=trace,
                    autoscale=True, max_seq=max_seq)
    out["arms"]["elastic_lt"] = _arm_summary(res_e, rate)
    res_f = run_arm(reqs, "mds", K_MDS, elastic=False,
                    trace=static_projection(trace), autoscale=False,
                    max_seq=max_seq)
    out["arms"]["fixed_mds"] = _arm_summary(res_f, rate)

    # -- acceptance: the claims this PR is allowed to make ----------------
    ce = out["arms"]["elastic_lt"]["cohorts"]
    cf = out["arms"]["fixed_mds"]["cohorts"]
    elastic_ratio = (ce["post_attainment"] or 0.0) / max(
        ce["pre_attainment"] or 0.0, EPS)
    fixed_loss = (cf["pre_attainment"] or 0.0) / max(
        cf["post_attainment"] or 0.0, EPS)
    out["acceptance"] = {
        # elastic LT holds goodput through the trace: post-churn cohort
        # attainment within 10% of the pre-churn cohort
        "elastic_pre_attainment": ce["pre_attainment"],
        "elastic_post_attainment": ce["post_attainment"],
        "elastic_post_over_pre": elastic_ratio,
        "elastic_holds_goodput": elastic_ratio >= 0.9,
        # the static fleet collapses on the same departures: >= 2x loss
        "fixed_pre_attainment": cf["pre_attainment"],
        "fixed_post_attainment": cf["post_attainment"],
        "fixed_loss_factor": min(fixed_loss, 1e6),
        "fixed_loses_2x": fixed_loss >= 2.0,
        # and elastic beats fixed outright on the post-churn cohort
        "elastic_beats_fixed": ((ce["post_attainment"] or 0.0)
                                >= (cf["post_attainment"] or 0.0)),
        "elastic_goodput_rps": out["arms"]["elastic_lt"]["goodput_rps"],
        "fixed_goodput_rps": out["arms"]["fixed_mds"]["goodput_rps"],
    }
    acc = out["acceptance"]
    csv.add("elastic_post_over_pre", elastic_ratio * 100.0,
            "percent of pre-churn attainment the elastic LT arm holds "
            "post-churn")
    csv.add("elastic_fixed_loss_factor", acc["fixed_loss_factor"],
            "x attainment lost by the static mds(4,3) fleet post-churn")
    csv.add("elastic_goodput_rps", acc["elastic_goodput_rps"],
            "req/s within e2e deadline, elastic LT under churn")
    csv.add("fixed_goodput_rps", acc["fixed_goodput_rps"],
            "req/s within e2e deadline, fixed-n mds under churn")
    name = "BENCH_elastic_quick.json" if quick else "BENCH_elastic.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"churn cohorts (arrive <{RESTART_T0:g}s vs >=): elastic "
          f"{ce['pre_attainment']:.2f} -> {ce['post_attainment']:.2f} "
          f"({elastic_ratio:+.0%} of pre) | fixed "
          f"{cf['pre_attainment']:.2f} -> {cf['post_attainment']:.2f} "
          f"({acc['fixed_loss_factor']:.1f}x loss)")
    alive = out["arms"]["elastic_lt"].get("alive_workers", {})
    print(f"fleet: elastic alive min/max {alive.get('min')}/"
          f"{alive.get('max')}, goodput elastic "
          f"{acc['elastic_goodput_rps']:.1f} vs fixed "
          f"{acc['fixed_goodput_rps']:.1f} req/s (wrote {path.name})")
    return out


if __name__ == "__main__":
    run(Csv(), quick="--quick" in sys.argv[1:])
