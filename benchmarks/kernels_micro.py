"""Kernel microbenchmarks (interpret mode on CPU — wall time is a
correctness-path proxy, not TPU perf; roofline terms come from the
dry-run instead)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.coding import vandermonde_generator
from repro.kernels.ops import conv2d_subtask, mds_encode, ssd_chunk

from .common import Csv, timed


def run(csv: Csv):
    # MDS encode: paper-shape (n=10, k=6) over a VGG conv4 partition
    G = jnp.asarray(vandermonde_generator(10, 6), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 512 * 30 * 8), jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(mds_encode(G, x, interpret=True)))
    csv.add("kernels/mds_encode_10x6", us, f"elems={x.size}")

    # conv2d: one worker subtask of VGG16 conv3_1 split k=6
    xw = jax.random.normal(jax.random.PRNGKey(1), (128, 58, 12), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 128, 3, 3), jnp.float32) * 0.03
    _, us = timed(lambda: jax.block_until_ready(
        conv2d_subtask(xw, w, 1, interpret=True)))
    csv.add("kernels/conv2d_subtask", us, "c128->256 h58 w12 k3")

    # ssd chunk: mamba2-2.7b-like tile (reduced H for CPU interpret)
    B, L, H, P, N = 1, 64, 8, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    args = (jax.random.normal(ks[0], (B, L, H, P), jnp.float32),
            jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))),
            -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3),
            jax.random.normal(ks[3], (B, L, N), jnp.float32),
            jax.random.normal(ks[4], (B, L, N), jnp.float32),
            jnp.zeros((B, H, P, N), jnp.float32))
    _, us = timed(lambda: jax.block_until_ready(
        ssd_chunk(*args, interpret=True)[0]))
    csv.add("kernels/ssd_chunk", us, f"L{L} H{H} P{P} N{N}")


if __name__ == "__main__":
    run(Csv())
