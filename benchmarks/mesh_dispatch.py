"""Coded dispatch wall-clock: threaded pool vs shard_map mesh (ISSUE 8).

Measures REAL device wall-clock (CPU in CI — the host platform is split
into 8 XLA devices, so the mesh arm is genuine SPMD) for the same coded
matmul / conv2d across schemes x (n, k) on both implementations of the
``dist/backend.py`` seam:

* **threads** — ``CodedExecutor`` on its default real clock, each piece an
  eagerly-encoded thunk on the worker pool (true k-th-arrival exit);
* **mesh** — ``MeshExecutor``, the whole op one jitted shard_map program
  (Pallas encode -> per-slice GEMM/conv -> sharded decode), compiled once
  per (scheme, shape) and replayed.

The two arms are NOT a straggler experiment (no faults injected): they
price the dispatch substrate itself — thread hop + per-piece Python vs a
single compiled SPMD launch.  Acceptance asserts what must always hold —
bitwise-identical decoded outputs, compile-once on the mesh, positive
wall-clocks — and records the speed ratio as telemetry only (CI machines
are too noisy to gate on cross-backend timing).

Run: PYTHONPATH=src python -m benchmarks.mesh_dispatch [--quick]
"""
from __future__ import annotations

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import json
import pathlib
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.coded_conv import coded_conv2d
from repro.core.coded_linear import coded_matmul
from repro.core.schemes import get_scheme
from repro.core.splitting import ConvSpec
from repro.dist import (CodedExecutor, DeterministicDelay, FakeClock,
                        MeshExecutor)

from .common import Csv

# (scheme, n, k) matrix; k=None lets structural schemes derive their own
MATMUL_ARMS = [("mds", 4, 3), ("mds", 8, 6), ("lt", 4, 3),
               ("replication", 4, None), ("uncoded", 4, None)]
CONV_ARMS = [("mds", 4, 3), ("replication", 4, None)]
QUICK_MATMUL = [("mds", 4, 3), ("replication", 4, None)]
QUICK_CONV = [("mds", 4, 3)]


def _scheme(name, n, k):
    cls = get_scheme(name)
    return cls.make(n, k) if k is not None else cls.make(n)


def _time(fn, repeats: int) -> float:
    """Mean wall seconds per call, result forced to the host each call."""
    fn()  # warmup: compile + first dispatch outside the timed window
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def _bitwise(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.tobytes() == b.tobytes()


def _arm(label, call_threads, call_mesh, call_det, mesh_ex, repeats) -> dict:
    """Time both substrates on their REAL clocks, then check bitwise
    equality against a deterministic-clock threaded run: under a real
    clock the k-th-ARRIVAL subset is racy, so the threaded decode may
    legitimately consume a different subset call-to-call — the contract
    is subset-for-subset byte equality, which the deterministic pool
    (uniform virtual delays -> arrival order 0..n-1, the mesh's modeled
    order) pins down."""
    wall_t = _time(call_threads, repeats)
    wall_m = _time(call_mesh, repeats)
    return {
        "label": label,
        "threads_wall_ms": wall_t * 1e3,
        "mesh_wall_ms": wall_m * 1e3,
        "mesh_over_threads": wall_m / max(wall_t, 1e-12),
        "mesh_compiles": mesh_ex.compile_count,
        "bitwise_equal": _bitwise(call_det(), call_mesh()),
    }


def run(csv: Csv, quick: bool = False) -> dict:
    repeats = 2 if quick else 5
    t_tok, d = (64, 64) if quick else (256, 256)
    mm_arms = QUICK_MATMUL if quick else MATMUL_ARMS
    cv_arms = QUICK_CONV if quick else CONV_ARMS
    spec = (ConvSpec(c_in=8, c_out=8, h_in=16, w_in=34, kernel=3, stride=1,
                     batch=1) if quick else
            ConvSpec(c_in=16, c_out=16, h_in=32, w_in=66, kernel=3,
                     stride=1, batch=2))
    rng = np.random.default_rng(0)
    out: dict = {
        "devices": jax.device_count(),
        "platform": jax.devices()[0].platform,
        "repeats": repeats,
        "matmul_shape": [t_tok, d, d],
        "conv_spec": {"c_in": spec.c_in, "c_out": spec.c_out,
                      "h_in": spec.h_in, "w_in": spec.w_in,
                      "kernel": spec.kernel, "batch": spec.batch},
        "matmul": [], "conv2d": [],
    }

    x = jnp.asarray(rng.normal(size=(t_tok, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
    for name, n, k in mm_arms:
        code = _scheme(name, n, k)
        with CodedExecutor(code.n) as ex_t, MeshExecutor() as ex_m, \
                CodedExecutor(code.n, clock=FakeClock(),
                              delay_model=DeterministicDelay(1.0)) as ex_d:
            out["matmul"].append(_arm(
                f"{name}({code.n},{code.k})",
                lambda: coded_matmul(x, w, code, executor=ex_t),
                lambda: coded_matmul(x, w, code, executor=ex_m),
                lambda: coded_matmul(x, w, code, executor=ex_d),
                ex_m, repeats))

    xc = jnp.asarray(rng.normal(
        size=(spec.batch, spec.c_in, spec.h_in, spec.w_in)), jnp.float32)
    wc = jnp.asarray(rng.normal(
        size=(spec.c_out, spec.c_in, spec.kernel, spec.kernel)), jnp.float32)
    for name, n, k in cv_arms:
        code = _scheme(name, n, k)
        with CodedExecutor(code.n) as ex_t, MeshExecutor() as ex_m, \
                CodedExecutor(code.n, clock=FakeClock(),
                              delay_model=DeterministicDelay(1.0)) as ex_d:
            out["conv2d"].append(_arm(
                f"{name}({code.n},{code.k})",
                lambda: coded_conv2d(xc, wc, code, spec, executor=ex_t),
                lambda: coded_conv2d(xc, wc, code, spec, executor=ex_m),
                lambda: coded_conv2d(xc, wc, code, spec, executor=ex_d),
                ex_m, repeats))

    arms = out["matmul"] + out["conv2d"]
    out["acceptance"] = {
        # the tentpole contract: both backends decode to the same bytes
        "all_bitwise_equal": all(a["bitwise_equal"] for a in arms),
        # one program build per (scheme, shape); replays hit the cache
        "mesh_compile_once": all(a["mesh_compiles"] == 1 for a in arms),
        # real device wall-clock was measured on both substrates
        "threads_wall_positive": all(a["threads_wall_ms"] > 0.0
                                     for a in arms),
        "mesh_wall_positive": all(a["mesh_wall_ms"] > 0.0 for a in arms),
        "n_arms": len(arms),
        "devices": out["devices"],
    }
    for a in out["matmul"]:
        csv.add(f"mesh_matmul_{a['label']}_ms", a["mesh_wall_ms"],
                "mesh backend wall ms/call, coded matmul "
                f"{out['matmul_shape']}")
        csv.add(f"threads_matmul_{a['label']}_ms", a["threads_wall_ms"],
                "threaded backend wall ms/call, same op")
    for a in out["conv2d"]:
        csv.add(f"mesh_conv_{a['label']}_ms", a["mesh_wall_ms"],
                "mesh backend wall ms/call, coded conv2d")
        csv.add(f"threads_conv_{a['label']}_ms", a["threads_wall_ms"],
                "threaded backend wall ms/call, same op")
    name = "BENCH_mesh_quick.json" if quick else "BENCH_mesh.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    acc = out["acceptance"]
    print(f"mesh dispatch on {out['devices']} {out['platform']} devices: "
          f"{acc['n_arms']} arms, bitwise_equal={acc['all_bitwise_equal']}, "
          f"compile_once={acc['mesh_compile_once']} (wrote {path.name})")
    for a in arms:
        print(f"  {a['label']:>18}: threads {a['threads_wall_ms']:8.2f} ms "
              f"| mesh {a['mesh_wall_ms']:8.2f} ms "
              f"({a['mesh_over_threads']:.2f}x)")
    return out


if __name__ == "__main__":
    run(Csv(), quick="--quick" in sys.argv[1:])
