# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import os
import sys
import time

# the mesh-dispatch bench needs multiple XLA devices; the split must be
# requested before anything initializes the jax backend (benchmarks.run is
# the entry point, so this is the one place early enough for every bench)
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

from .common import Csv


def main() -> None:
    from . import (
        adaptive_replan,
        elastic_churn,
        explain_forensics,
        ext_hetero,
        fig4_overhead,
        fig5_scenario1,
        fig6_scenario23,
        fig7_layer_breakdown,
        fig9_approx_gap,
        fig10_param_impact,
        kernels_micro,
        mesh_dispatch,
        pipeline_depth,
        roofline,
        serving_load,
        sim_speedup,
        table1_k_approx,
    )

    only = sys.argv[1] if len(sys.argv) > 1 else None
    csv = Csv()
    benches = [
        ("fig7", fig7_layer_breakdown.run),
        ("fig4", fig4_overhead.run),
        ("table1", table1_k_approx.run),
        ("fig5", fig5_scenario1.run),
        ("fig6", fig6_scenario23.run),
        ("fig9", fig9_approx_gap.run),
        ("fig10", fig10_param_impact.run),
        ("ext_hetero", ext_hetero.run),
        ("adaptive", adaptive_replan.run),
        ("pipeline", pipeline_depth.run),
        ("serving", serving_load.run),
        ("prefill", serving_load.run_prefill),
        ("elastic", elastic_churn.run),
        ("explain", explain_forensics.run),
        ("mesh", mesh_dispatch.run),
        ("kernels", kernels_micro.run),
        ("roofline", roofline.run),
        ("sim_speedup", sim_speedup.run),
    ]
    for name, fn in benches:
        if only and only != name:
            continue
        t0 = time.time()
        fn(csv)
        print(f"# [{name}] done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
