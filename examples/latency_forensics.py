"""Tail-latency forensics walkthrough: trace it, explain it, re-plan it.

DESIGN.md §15 in one script, on a deterministic virtual clock:

1. **trace** — a `TraceRecorder` on the executor/pool captures
   piece/phase/run spans while a serving loop runs; export them as
   JSONL and as a Chrome trace (load `/tmp/forensics_trace.json` in
   Perfetto / chrome://tracing);
2. **explain** — mid-stream, worker 1's layer-2 compute stage slows
   12x.  Per-stage features + SLO breach flags go through
   `explain_breaches`, which names the culprit (worker, phase, layer),
   dates the shift, and scores itself;
3. **re-plan** — the same per-layer evidence feeds
   `AdaptivePlanner.replan_segments`: the regime shift resets the
   estimator window, per-layer scales expose the slowed layer, and the
   netplan cut DP moves a segment boundary to isolate it.

Run: PYTHONPATH=src python examples/latency_forensics.py
"""
import json
import pathlib

import jax.numpy as jnp

from repro.core.latency import PhaseSizes, SystemParams
from repro.core.netplan import LayerInfo, compile_plan
from repro.core.schemes import get_scheme
from repro.core.splitting import ConvSpec
from repro.dist import (CodedExecutor, FakeClock, LayerSlowdown,
                        SegmentDelay, per_layer_sizes)
from repro.dist.adaptive import AdaptivePlanner
from repro.telemetry import (TraceRecorder, detect_regimes,
                             explain_breaches, features_from_report,
                             to_chrome_trace, to_jsonl)

PARAMS = SystemParams(mu_m=2.5e9, theta_m=4e-10, mu_cmp=4e9,
                      theta_cmp=1.35e-9, mu_rec=1.5e7, theta_rec=3e-7,
                      mu_sen=1.5e7, theta_sen=3e-7)
N, N_REQ, SHIFT = 4, 30, 15
LSZ = per_layer_sizes([PhaseSizes(n_enc=0.0, n_cmp=2e6, n_rec=1e4,
                                  n_sen=1e4, n_dec=0.0)] * 4)

# -- 1. trace + scripted drift: worker 1's layer-2 stage slows 12x -------
rec = TraceRecorder()
rows, walls = [], []
with CodedExecutor(N, clock=FakeClock()) as ex:
    ex.trace_sink = rec
    ex.pool.trace_sink = rec
    for r in range(N_REQ):
        delay = SegmentDelay(PARAMS, LSZ, seed=100 + r)
        if r >= SHIFT:
            delay = LayerSlowdown(delay, {1: {2: 12.0}})
        # uncoded k=n: every chain gates completion, so the slow worker
        # actually breaches instead of being cancelled by k-of-n
        ex.run(get_scheme("uncoded").make(N),
               [lambda: jnp.ones((2, 2))] * N,
               delay_model=delay, gather_all=True)
        rows.append(features_from_report(ex.last_report, per_layer=True))
        walls.append(ex.last_report.t_complete - ex.last_report.t_submit)

chrome = pathlib.Path("/tmp/forensics_trace.json")
chrome.write_text(json.dumps(to_chrome_trace(rec.spans)))
print(f"traced {len(rec.spans)} spans "
      f"({len(rec.by_name('piece'))} pieces, {len(rec.by_name('run'))} "
      f"runs) -> {chrome} + {len(to_jsonl(rec.spans).splitlines())} "
      f"JSONL lines")

# -- 2. explain the SLO breaches -----------------------------------------
slo = 1.05 * max(walls[:SHIFT])
breach = [w > slo for w in walls]
report = explain_breaches(rows, breach, [float(r) for r in range(N_REQ)])
print(f"\nSLO {slo*1e3:.2f} ms -> {sum(breach)} breaches; "
      f"explainer ({report.method}) says:")
print(" ", report.describe())

# -- 3. re-plan: the same evidence moves a segment boundary --------------
def chain(depth=6, size=16, c=16):
    out, s = [], size
    for j in range(depth):
        spec = ConvSpec(c_in=3 if j == 0 else c, c_out=c, h_in=s, w_in=s,
                        kernel=3, stride=1)
        out.append(LayerInfo(f"conv{j}", spec, True, act=None, pad=0))
        s = spec.w_out
    return tuple(out)

from repro.core.netplan import SegmentStep, segment_layer_sizes

layers = chain()
static = compile_plan(layers, 10, PARAMS, "mds")
planner = AdaptivePlanner(PARAMS, min_samples=4)
spans = []
with CodedExecutor(10, clock=FakeClock(), timeout_s=300.0) as ex:
    for i in range(N_REQ):
        total = 0.0
        for step in static.steps:
            if not isinstance(step, SegmentStep):
                continue
            specs = [li.spec for li in layers[step.start:step.stop]]
            pads = [li.pad for li in layers[step.start:step.stop]]
            lsz = per_layer_sizes(segment_layer_sizes(
                specs, pads, step.scheme, step.split))
            d = SegmentDelay(PARAMS, lsz, seed=1000 + 37 * i)
            if i >= 10 and step.start <= 3 < step.stop:
                # layer 3's compute slows 8x on EVERY worker
                d = LayerSlowdown(d, {w: {3 - step.start: 8.0}
                                      for w in range(10)})
            ex.run(step.scheme, [lambda: jnp.ones((1, 1))] * step.scheme.n,
                   delay_model=d, gather_all=True)
            rep = ex.last_report
            planner.observe_report(rep, lsz, at=float(i),
                                   layer_ids=range(step.start, step.stop))
            total += max(t.t_arrival - rep.t_submit for t in rep.timings)
        spans.append(total)

sp = detect_regimes(spans)
planner.reset_at(float(sp.split))
replan = planner.replan_segments(layers, 10, scheme="mds")
fmt = lambda p: " + ".join(f"[{s.start},{s.stop}) k={s.k}"
                           for s in p.segments)
print(f"\nregime shift detected at request {sp.split} "
      f"(lift {sp.lift:.2f}); per-layer scales "
      f"{[round(s, 2) for s in planner.layer_scales(range(6))]}")
print(f"static plan: {fmt(static)}")
print(f"re-planned:  {fmt(replan)}  <- the slowed layer 3 is isolated "
      f"behind its own cut")
