"""Prefill-efficient serving: packing, chunking, and coded prefix caching.

DESIGN.md §14 in one demo.  A Zipf-reused shared-prefix workload (system
prompts / few-shot templates) is served twice on one engine + worker pool:

1. **cold pass** — every prompt runs a coded prefill, but co-admitted
   mixed-length prompts are *packed* into ONE padded+masked coded call
   (n pieces total, never per-request) and long prompts are *chunked*
   into scheduler-step-sized prefill slices interleaved with decode
   steps.  Finished prefills deposit their per-request KV blocks into a
   radix :class:`PrefixCache`.
2. **warm pass** — the same traffic replayed: the cache restores each
   prompt's shared-prefix KV and only the sub-``k`` fresh suffix remains,
   which runs master-local — the pool sees ZERO prefill pieces, proven
   on the dispatch counters, and the tokens stay bitwise-identical.

Cached KV is post-decode plaintext, so coding-layer events (retargeting
(n, k), churn, backend swaps) never invalidate it.

Run: PYTHONPATH=src python examples/prefix_caching.py
"""
import numpy as np
import jax.numpy as jnp

from repro.dist import CodedExecutor, DeterministicDelay, FakeClock
from repro.models.model import ModelConfig
from repro.serving import (Engine, LengthDist, PoissonArrivals, PrefixCache,
                           ServingScheduler, SharedPrefixDist, Workload,
                           summarize)

BLOCK = 8  # radix-cache block == shared-prefix family length

cfg = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab=64, gated=False,
                  coded_n=4, coded_k=3, coded_scheme="mds",
                  dtype=jnp.float32)

# 3 prefix families of 8 tokens, Zipf-reused, plus a fresh 1-2 token
# suffix per request: suffix < k, so a family hit never reaches the pool.
wl = Workload(PoissonArrivals(rate=0.4), LengthDist.fixed(1),
              LengthDist((2, 3)), vocab=cfg.vocab, seed=7,
              shared_prefix=SharedPrefixDist(
                  n_families=3, prefix_len=BLOCK,
                  suffix_len=LengthDist((1, 2)), zipf_a=1.2,
                  vocab=cfg.vocab, seed=11))
reqs = wl.generate(12)

cache = PrefixCache(capacity_bytes=8 << 20, block=BLOCK)
with CodedExecutor(4, clock=FakeClock(),
                   delay_model=DeterministicDelay(0.01)) as ex:
    eng = Engine(cfg, seed=0, executor=ex)
    results = []
    for label in ("cold", "warm"):
        # chunk_tokens bounds per-step prefill work: prompts at or under
        # it (and cache-cold) pack into one coded call; anything longer,
        # or resuming atop restored prefix KV, streams in chunks.
        sched = ServingScheduler(eng, max_seq=wl.max_seq, max_batch=4,
                                 packed=True, chunk_tokens=2 * BLOCK,
                                 prefix_cache=cache)
        res = sched.serve(reqs)
        results.append(res)
        s = summarize(res)
        pieces = sum(st.prefill_dispatches for st in res.steps)
        print(f"{label:4s} pass: prefill pieces {pieces:3d}, packed tokens "
              f"{s['packed_tokens_total']:2d} (+{s['packed_pad_tokens_total']}"
              f" pad), chunks {s['prefill_chunks_total']}, "
              f"hit rate {s['prefix_hit_rate']:.0%}")

cold, warm = results
same = all(np.array_equal(a.tokens, b.tokens)
           for a, b in zip(cold.completions, warm.completions))
warm_pieces = sum(st.prefill_dispatches for st in warm.steps)
print(f"\ncache: {cache.stats.hits}/{cache.stats.lookups} lookups hit, "
      f"{cache.n_blocks} blocks resident ({cache.bytes / 1e3:.0f} kB)")
print(f"warm replay pool-dispatch-free: {warm_pieces == 0}; "
      f"tokens bitwise-identical across passes: {same}")
