"""End-to-end CoCoI CNN inference through the network-level plan compiler.

1. Compiles the small CNN into coded segments (core/netplan.py) under a
   few schemes and checks the segment-pipelined logits match local
   inference, while counting the master encode/decode boundary ops the
   run actually performs (2 per segment, not 2 per layer).
2. Compiles VGG16 and prints the per-layer vs segment plan structure:
   boundary ops, master<->worker transfer bytes, modeled latency.
3. Simulates the paper's scenario-2 (device failures) on VGG16 and prints
   the latency comparison CoCoI vs uncoded vs replication.

Run: PYTHONPATH=src python examples/coded_cnn_inference.py
"""
import jax
import jax.numpy as jnp

from repro.core import SystemParams, SimScenario, compile_plan, k_circ
from repro.core.coded_conv import boundary_op_counter
from repro.core.runtime import simulate_network
from repro.models import init_small_cnn, small_cnn_forward
from repro.models.cnn import SMALL_CNN_PARAMS, small_cnn_layers, vgg16_conv_specs

# --- 1. numerical end-to-end: segment-compiled CNN == local CNN ----------
params = init_small_cnn(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32, 32), jnp.float32)
logits_local = small_cnn_forward(params, x)

layers = small_cnn_layers(32)
for scheme in ("mds", "replication", "uncoded"):
    plan = compile_plan(layers, 6, SMALL_CNN_PARAMS, scheme)
    with boundary_op_counter() as ops:
        logits = small_cnn_forward(params, x, plan=plan)
    err = float(jnp.max(jnp.abs(logits - logits_local)))
    same = bool((jnp.argmax(logits, -1) == jnp.argmax(logits_local, -1)).all())
    print(f"{scheme:12s}: {plan.n_segments} segments, "
          f"{ops['encode'] + ops['decode']} boundary ops, "
          f"max abs err {err:.2e}, classes identical: {same}")

# --- 2. VGG16 plan structure: per-layer vs segment ----------------------
sysp = SystemParams(mu_m=2.5e9, theta_m=4e-10, mu_cmp=4e9, theta_cmp=1.35e-9,
                    mu_rec=1.5e7, theta_rec=3e-7, mu_sen=1.5e7, theta_sen=3e-7)
vgg = vgg16_conv_specs(224, sysp)
for scheme in ("replication", "mds"):
    seg = compile_plan(vgg, 10, sysp, scheme)
    per = compile_plan(vgg, 10, sysp, scheme, max_depth=1)
    print(f"\nVGG16 {scheme}: per-layer {per.boundary_coding_ops} boundary "
          f"ops / {per.master_worker_bytes / 1e6:.1f} MB  ->  segment "
          f"{seg.boundary_coding_ops} ops / "
          f"{seg.master_worker_bytes / 1e6:.1f} MB "
          f"({1 - seg.est_latency_s / per.est_latency_s:+.1%} modeled latency)")
    print("  " + seg.describe())

# --- 3. latency simulation on VGG16 under failures ----------------------
specs = [li.spec for li in vgg if li.type1]
ks = [min(k_circ(s, 10, sysp), 8) for s in specs]
print()
for nf in (0, 1, 2):
    sc = SimScenario(n_fail=nf)
    coded = simulate_network(specs, 10, sysp, "coded", ks=ks, scenario=sc,
                             trials=10)
    unc = simulate_network(specs, 10, sysp, "uncoded", scenario=sc, trials=10)
    rep = simulate_network(specs, 10, sysp, "replication", scenario=sc,
                           trials=10)
    print(f"failures={nf}: CoCoI {coded.mean():6.2f}s | uncoded "
          f"{unc.mean():6.2f}s | replication {rep.mean():6.2f}s | "
          f"reduction {1 - coded.mean() / unc.mean():+.1%}")
