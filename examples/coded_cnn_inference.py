"""End-to-end CoCoI CNN inference + straggler simulation.

1. Runs a small CNN where every type-1 conv executes through the coded
   pipeline and checks the logits match local inference bit-for-bit-ish.
2. Simulates the paper's scenario-2 (device failures) on VGG16 and prints
   the latency comparison CoCoI vs uncoded vs replication.

Run: PYTHONPATH=src python examples/coded_cnn_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MDSCode, SystemParams, SimScenario
from repro.core.runtime import simulate_network
from repro.models import init_small_cnn, small_cnn_forward
from repro.models.cnn import vgg16_conv_specs

# --- 1. numerical end-to-end: coded CNN == local CNN --------------------
params = init_small_cnn(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32, 32), jnp.float32)
logits_local = small_cnn_forward(params, x)
code = MDSCode(n=6, k=4)
logits_coded = small_cnn_forward(params, x, code=code, subset=[1, 2, 4, 5])
err = float(jnp.max(jnp.abs(logits_coded - logits_local)))
print(f"coded CNN inference matches local: max abs err = {err:.2e}")
same = bool((jnp.argmax(logits_coded, -1) == jnp.argmax(logits_local, -1)).all())
print(f"predicted classes identical: {same}")

# --- 2. latency simulation on VGG16 under failures ----------------------
sysp = SystemParams(mu_m=2.5e9, theta_m=4e-10, mu_cmp=4e9, theta_cmp=1.35e-9,
                    mu_rec=1.5e7, theta_rec=3e-7, mu_sen=1.5e7, theta_sen=3e-7)
specs = [li.spec for li in vgg16_conv_specs() if li.type1]
from repro.core import k_circ
# plan k per layer, keeping r >= 2 redundancy for the failure scenarios
ks = [min(k_circ(s, 10, sysp), 8) for s in specs]
for nf in (0, 1, 2):
    sc = SimScenario(n_fail=nf)
    coded = simulate_network(specs, 10, sysp, "coded", ks=ks, scenario=sc,
                             trials=10)
    unc = simulate_network(specs, 10, sysp, "uncoded", scenario=sc, trials=10)
    rep = simulate_network(specs, 10, sysp, "replication", scenario=sc,
                           trials=10)
    print(f"failures={nf}: CoCoI {coded.mean():6.2f}s | uncoded "
          f"{unc.mean():6.2f}s | replication {rep.mean():6.2f}s | "
          f"reduction {1 - coded.mean() / unc.mean():+.1%}")
