"""Train a reduced-config model end-to-end on CPU (training substrate demo:
data pipeline -> model -> AdamW+WSD -> checkpoint).

Run: PYTHONPATH=src python examples/train_tiny.py
"""
import tempfile

from repro.configs import smoke_config
from repro.launch.train import train_loop

cfg = smoke_config("minicpm-2b")  # exercises the WSD schedule
with tempfile.TemporaryDirectory() as d:
    _, losses = train_loop(cfg, steps=40, batch=4, seq=48, ckpt_dir=d,
                           log_every=10)
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")
