"""Adaptive serving walkthrough: the engine re-plans as stragglers drift.

`Engine(adaptive=True)` closes the telemetry loop (DESIGN.md §8): every
coded FFN GEMM runs on the worker pool, its per-piece timings feed
per-worker (mu, theta) profiles, and the next GEMM re-solves k° and the
piece allocation from them.  This demo serves three phases of traffic on
a deterministic virtual clock:

1. healthy fleet — the allocation stays balanced;
2. worker 3 drifts to 8x slower — a gather-all probe surfaces it (k-of-n
   cancellation hides stragglers from pure completion telemetry) and the
   allocation starves it;
3. worker 3 recovers — the next probe sees it healthy again and pieces
   flow back.

Run: PYTHONPATH=src python examples/adaptive_serving.py
"""
import numpy as np
import jax.numpy as jnp

from repro.dist import (CodedExecutor, DeterministicDelay, FakeClock,
                        FaultPlan, StragglerDrift, gemm_spec)
from repro.models.model import ModelConfig
from repro.serving.engine import Engine, Request

cfg = ModelConfig(name="tiny", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=128, dtype=jnp.float32)
ex = CodedExecutor(4, clock=FakeClock(),
                   delay_model=DeterministicDelay(1.0))
engine = Engine(cfg, coded=(4, 2), scheme="mds", seed=0, executor=ex,
                adaptive=True)
engine.executor.probe_every = 2          # probe often: short demo
engine.executor.planner.bank.min_samples = 3
engine.executor.planner.bank.window = 8
engine.executor.planner.bank.alpha = 0.5

drift = StragglerDrift((
    (2, FaultPlan(straggler={3: 12.0})),  # phase 2: worker 3 drifts 12x
    (4, FaultPlan()),                     # phase 3: worker 3 recovers
))

rid = 0
for phase, label in ((0, "healthy fleet"), (1, "healthy fleet"),
                     (2, "worker 3 straggling 12x"), (3, "worker 3 straggling 12x"),
                     (4, "worker 3 recovered"), (5, "worker 3 recovered"),
                     (6, "worker 3 recovered"), (7, "worker 3 recovered")):
    engine.executor.pool.fault_plan = drift.plan_at(phase)
    reqs = [Request(rid + j, np.arange(6, dtype=np.int32), max_new=2)
            for j in range(4)]
    rid += len(reqs)
    engine.generate(reqs)
    # the allocation the next (non-probe) coded GEMM will use
    plan = engine.executor.planner.plan(gemm_spec(6, 32, 64), 4, 4,
                                        fixed_k=2)
    pieces = plan.assignment or [1, 1, 1, 1]
    speeds = engine.executor.planner.speeds(4)
    rel = [round(s / max(speeds), 2) for s in speeds]
    print(f"step {phase} ({label:26s}) pieces/worker {pieces} "
          f"rel speeds {rel}")

print("\nfinal per-worker profiles (per-unit round-trip mean):")
for w, p in sorted(engine.executor.planner.bank.profiles.items()):
    if p.ready:
        print(f"  worker {w}: mean {p.mean():.3g} "
              f"({p.n_observed} observations)")
ex.close()
