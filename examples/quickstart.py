"""Quickstart: CoCoI coded distributed convolution in ~40 lines.

Splits a conv layer's input into k=4 overlapping partitions, encodes them
into n=6 coded subtasks with a Vandermonde MDS code, executes the subtasks,
and recovers the EXACT output from the 4 "fastest" workers — then asks the
planner what k it would pick for a Raspberry-Pi-class cluster.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ConvSpec, MDSCode, SystemParams,
    coded_conv2d, conv2d, k_circ, straggling_index_R,
)

# a VGG16-conv3_1-like layer: 128 -> 256 channels, 58x58 padded input
spec = ConvSpec(c_in=128, c_out=256, h_in=58, w_in=58, kernel=3, stride=1)
code = MDSCode(n=6, k=4)  # tolerate r = 2 stragglers/failures

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (1, 128, 58, 58), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (256, 128, 3, 3),
                      jnp.float32) * 0.05

ref = conv2d(x, w)
# pretend workers 1 and 3 straggle: decode from {0, 2, 4, 5}
out = coded_conv2d(x, w, code, spec, subset=[0, 2, 4, 5])
err = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
print(f"coded output matches uncoded conv: rel err = {err:.2e}")

# optimal splitting for a 10-worker Pi cluster (paper §IV)
params = SystemParams(mu_cmp=1.25e9, theta_cmp=8e-10,
                      mu_rec=4e7, theta_rec=8e-8,
                      mu_sen=4e7, theta_sen=8e-8)
print(f"straggling index R = {straggling_index_R(spec, params):.2f} "
      f"(R <= 1 => coded provably wins, Prop. 2)")
print(f"planner's k° for n=10 workers: {k_circ(spec, 10, params)}")
