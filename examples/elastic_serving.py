"""Elastic coded serving under churn (DESIGN.md §12).

The fleet is never static: mid-trace a flash crowd commissions two
fresh workers, then a rolling restart takes base workers 1 and 2 down
permanently (a restarted device loses its resident state) with
replacements joining shortly after.  The elastic executor moves n with
the live fleet before every coded GEMM — the rateless LT scheme keeps
k, so joiners simply mean more coded rows, never a re-encode — and a
queue-driven autoscaler backfills whenever the backlog costs more than
a worker.  Compare the membership timeline and per-epoch goodput with
a static mds(4,3) fleet suffering the same departures: the static arm
round-robins every GEMM's 4 pieces two-deep onto the 2 survivors and
its queue diverges.

Everything is deterministic virtual time: the same seeds and the same
ChurnSchedule replay the same run bit-for-bit.

Run: PYTHONPATH=src python examples/elastic_serving.py
"""
import jax.numpy as jnp

from repro.core.latency import SystemParams, phase_sizes
from repro.dist import (Autoscaler, ChurnSchedule, CodedExecutor, FakeClock,
                        ShiftExpDelay, gemm_spec)
from repro.models.model import ModelConfig
from repro.serving import (Engine, LengthDist, PoissonArrivals,
                           ServingScheduler, Workload, summarize)

N_WORKERS, N, K = 4, 4, 3
RATE = 26.0           # offered requests/second
N_REQUESTS = 48
PIECE_S = 5e-3        # target mean piece round-trip: virtual ms scale

# flash crowd just ahead of the maintenance window, then a rolling
# restart of workers 1 and 2 (remove + replacement join 0.25 s later)
CHURN = (ChurnSchedule.flash_crowd(0.6, 2)
         + ChurnSchedule.rolling_restart((1, 2), 0.7,
                                         down_s=0.25, stagger_s=0.15))
STATIC = ChurnSchedule(tuple(e for e in CHURN.events
                             if e.action == "remove"))
DEADLINE_S = 100 * PIECE_S


def piece_delay(k: int, seed: int = 0) -> ShiftExpDelay:
    base = SystemParams()  # paper-testbed defaults
    sizes = phase_sizes(gemm_spec(8, 32, 64), N, k)
    mean = (base.rec.scaled(sizes.n_rec).mean()
            + base.cmp.scaled(sizes.n_cmp).mean()
            + base.sen.scaled(sizes.n_sen).mean())
    s = PIECE_S / mean
    params = SystemParams(
        mu_m=base.mu_m / s, theta_m=base.theta_m * s,
        mu_cmp=base.mu_cmp / s, theta_cmp=base.theta_cmp * s,
        mu_rec=base.mu_rec / s, theta_rec=base.theta_rec * s,
        mu_sen=base.mu_sen / s, theta_sen=base.theta_sen * s)
    return ShiftExpDelay(params, sizes, seed=seed)


workload = Workload(PoissonArrivals(RATE), LengthDist((6, 10)),
                    LengthDist((4, 8)), vocab=64, seed=11)
requests = workload.generate(N_REQUESTS)


def serve(scheme: str, *, elastic: bool, churn: ChurnSchedule,
          autoscale: bool):
    cfg = ModelConfig(name="elastic-demo", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      gated=False, dtype=jnp.float32, coded_n=N,
                      coded_k=K, coded_scheme=scheme)
    with CodedExecutor(N_WORKERS, clock=FakeClock(),
                       delay_model=piece_delay(K), timeout_s=600.0,
                       elastic=elastic) as ex:
        auto = (Autoscaler(ex.pool, min_workers=N_WORKERS, max_workers=8,
                           target_queue=1.0, alpha=0.7, cooldown_steps=3)
                if autoscale else None)
        eng = Engine(cfg, seed=0, executor=ex)
        sched = ServingScheduler(eng, max_seq=18, max_batch=8,
                                 master_call_s=5e-4, delay_seed_stride=1,
                                 churn=churn, autoscaler=auto)
        return sched.serve(requests)


for tag, scheme, elastic, churn in (
        ("elastic lt(fleet,3) + autoscaler", "lt", True, CHURN),
        ("static  mds(4,3), departures only", "mds", False, STATIC)):
    auto = elastic
    res = serve(scheme, elastic=elastic, churn=churn, autoscale=auto)
    s = summarize(res, deadline_s=DEADLINE_S, epoch_s=0.25)
    print(f"\n== {tag} ==")
    print(f"  goodput {s['goodput_rps']:.1f} req/s, attainment "
          f"{s['slo_attainment']:.0%}, p99 e2e {s['e2e_s']['p99']*1e3:.0f} ms")
    if "alive_workers" in s:
        a = s["alive_workers"]
        print(f"  fleet alive min/mean/max: {a['min']}/{a['mean']:.1f}/"
              f"{a['max']}")
    for t, action, w in s.get("membership", []):
        print(f"    t={t:6.3f}s  {action:6s} worker {w}")
    print("  per-epoch attainment:",
          ["%.2f" % e["attainment"] if e["attainment"] is not None else "-"
           for e in s["epochs"]])
