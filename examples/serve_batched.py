"""End-to-end driver (the paper's kind is INFERENCE): serve a small model
with batched requests, both uncoded and in CoCoI coded mode, and compare
outputs + throughput.

Run: PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

from repro.configs import smoke_config
from repro.serving import Engine, Request

cfg = smoke_config("gemma-2b")
rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 24, dtype=np.int32),
                max_new=12) for i in range(6)]

plain = Engine(cfg, seed=0)
coded = Engine(cfg, seed=0, coded=(6, 4))  # tolerate 2 stragglers per GEMM

out_plain = plain.generate(reqs)
out_coded = coded.generate(reqs)

match = all((a.tokens == b.tokens).all()
            for a, b in zip(out_plain, out_coded))
print(f"served {len(reqs)} requests (prompt 24, +12 tokens each)")
print(f"coded-mode generations identical to uncoded: {match}")
tot = sum(len(c.tokens) for c in out_plain)
print(f"uncoded wall: {out_plain[0].latency_s:.2f}s/batch; "
      f"coded wall: {out_coded[0].latency_s:.2f}s/batch "
      f"(CPU reference timing; straggler wins appear on the simulated "
      f"cluster, see examples/coded_cnn_inference.py)")
