"""Serving under load: coded vs uncoded tail latency while a straggler
drifts in (DESIGN.md §10).

Poisson traffic flows open-loop into the continuous-batching scheduler:
requests queue on arrival, join the running decode batch at prefill, and
leave at max_new.  Every co-scheduled step stacks all lanes' tokens into
the FFN GEMMs, so the coded engine issues ONE n-piece pool dispatch per
GEMM for the whole batch — counted on the real pool below, not assumed.
Mid-run worker 3 drifts to a 10x straggler; mds(4,3) keeps decoding at the
3rd arrival and cancels it, while the uncoded split must wait for all 4
pieces on every dispatching GEMM.  Everything runs in deterministic
virtual time (FakeClock pool + shift-exponential round-trips).

Run: PYTHONPATH=src python examples/serving_under_load.py
"""
import jax.numpy as jnp

from repro.core.latency import SystemParams, phase_sizes
from repro.dist import (CodedExecutor, FakeClock, FaultPlan, ShiftExpDelay,
                        StragglerDrift, gemm_spec)
from repro.models.model import ModelConfig
from repro.serving import (Engine, LengthDist, PoissonArrivals,
                           ServingScheduler, Workload, summarize)

N_WORKERS, N, K = 4, 4, 3
RATE = 40.0           # offered requests/second
N_REQUESTS = 40
DRIFT_AT_STEP = 5     # worker 3 goes 10x slower from this step on

PIECE_S = 5e-3  # target mean piece round-trip: a virtual timeline in ms


def piece_delay(k: int, seed: int = 0) -> ShiftExpDelay:
    """Testbed-class shift-exp round-trips for this model's FFN GEMM
    pieces, rescaled so the mean piece lands at PIECE_S."""
    base = SystemParams()  # paper-testbed defaults
    sizes = phase_sizes(gemm_spec(8, 32, 64), N, k)
    mean = (base.rec.scaled(sizes.n_rec).mean()
            + base.cmp.scaled(sizes.n_cmp).mean()
            + base.sen.scaled(sizes.n_sen).mean())
    s = PIECE_S / mean
    params = SystemParams(
        mu_m=base.mu_m / s, theta_m=base.theta_m * s,
        mu_cmp=base.mu_cmp / s, theta_cmp=base.theta_cmp * s,
        mu_rec=base.mu_rec / s, theta_rec=base.theta_rec * s,
        mu_sen=base.mu_sen / s, theta_sen=base.theta_sen * s)
    return ShiftExpDelay(params, sizes, seed=seed)
workload = Workload(PoissonArrivals(RATE), LengthDist((6, 10)),
                    LengthDist((4, 8)), vocab=64, seed=7)
requests = workload.generate(N_REQUESTS)
drift = StragglerDrift(((DRIFT_AT_STEP, FaultPlan(straggler={3: 10.0})),))


def serve(scheme: str, k: int):
    cfg = ModelConfig(name="demo", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab=64, gated=False,
                      dtype=jnp.float32, coded_n=N, coded_k=k,
                      coded_scheme=scheme)
    with CodedExecutor(N_WORKERS, clock=FakeClock(),
                       delay_model=piece_delay(k),
                       timeout_s=600.0) as ex:
        engine = Engine(cfg, seed=0, executor=ex)
        sched = ServingScheduler(engine, max_seq=workload.max_seq,
                                 max_batch=8, master_call_s=5e-4,
                                 fault_drift=drift, delay_seed_stride=1)
        result = sched.serve(requests)
    return result, summarize(result, deadline_s=0.5, ttft_deadline_s=0.1)


print(f"{N_REQUESTS} Poisson requests @ {RATE:g}/s, worker 3 drifts to "
      f"10x at step {DRIFT_AT_STEP}\n")
for scheme, k in (("mds", K), ("uncoded", N)):
    result, s = serve(scheme, k)
    pieces = sum(st.dispatches for st in result.steps)
    runs = sum(st.runs for st in result.steps)
    occ = s["batch_occupancy"]["mean"]
    print(f"[{scheme}({N},{k})]")
    print(f"  TTFT p50/p99: {s['ttft_s']['p50']*1e3:7.1f} / "
          f"{s['ttft_s']['p99']*1e3:7.1f} ms   "
          f"e2e p99: {s['e2e_s']['p99']*1e3:7.1f} ms")
    print(f"  goodput: {s['goodput_rps']:.1f} req/s "
          f"({s['slo_attainment']:.0%} in SLO), TTFT attainment "
          f"{s['ttft_attainment']:.0%}")
    print(f"  pool: {pieces} pieces over {runs} runs "
          f"({pieces // max(runs, 1)} per dispatch = n, batch occupancy "
          f"{occ:.1f})\n")
