"""Live k-of-n coded execution on the threaded worker pool (DESIGN.md §7).

Unlike examples/coded_cnn_inference.py — which *models* straggler latency
with the Monte-Carlo simulator — this demo actually executes a coded conv
layer on a WorkerPool under injected faults and measures the wall clock:

1. one worker straggling 25x: MDS (n, k) returns at the k-th arrival and
   cancels the straggler mid-sleep; uncoded must wait for it;
2. one dead worker: MDS decodes from the survivors, uncoded re-dispatches
   the lost piece and pays the retry;
3. heterogeneous workers: ``hetero.allocate_pieces`` routes proportionally
   more pieces to the fast worker.

Run: PYTHONPATH=src python examples/distributed_pool.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.coded_conv import coded_conv2d, conv2d
from repro.core.hetero import allocate_pieces
from repro.core.schemes import get_scheme
from repro.core.splitting import ConvSpec
from repro.dist import CodedExecutor, DeterministicDelay, FaultPlan, RealClock

N, K = 5, 3
PIECE_S = 0.03  # modeled healthy round-trip per piece

spec = ConvSpec(c_in=8, c_out=8, h_in=16, w_in=26, kernel=3, batch=2)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(2, 8, 16, 26)), jnp.float32)
w = jnp.asarray(rng.normal(size=(8, 8, 3, 3)), jnp.float32)
y_ref = conv2d(x, w, 1)


def run(scheme, fault_plan, label):
    ex = CodedExecutor(N, clock=RealClock(),
                       delay_model=DeterministicDelay(PIECE_S),
                       fault_plan=fault_plan)
    y = coded_conv2d(x, w, scheme, spec, executor=ex)
    r = ex.last_report
    err = float(jnp.max(jnp.abs(y - y_ref)))
    print(f"{label:26s} wall {r.wall_s * 1e3:7.1f} ms | subset {r.subset} | "
          f"cancelled {r.cancelled} | redispatched {len(r.redispatched)} | "
          f"max err {err:.2e}")
    ex.close()
    return r.wall_s


mds = get_scheme("mds").make(N, K)
unc = get_scheme("uncoded").make(N)

print(f"-- scenario: one worker straggles 25x ({N} workers, MDS k={K}) --")
straggle = FaultPlan(straggler={0: 25.0})
t_c = run(mds, straggle, f"CoCoI MDS({N},{K})")
t_u = run(unc, straggle, f"uncoded n={N}")
print(f"latency reduction: {1 - t_c / t_u:+.1%}\n")

print("-- scenario: one dead worker --")
dead = FaultPlan(dead=frozenset({1}))
t_c = run(mds, dead, f"CoCoI MDS({N},{K})")
t_u = run(unc, dead, f"uncoded n={N}")
print(f"latency reduction: {1 - t_c / t_u:+.1%}\n")

print("-- scenario: heterogeneous workers (one 6x faster) --")
speeds = [6.0, 1.0, 1.0]
counts = allocate_pieces(speeds, mds.n)
ex = CodedExecutor(3, clock=RealClock(),
                   delay_model=DeterministicDelay(
                       [PIECE_S / 6.0, PIECE_S, PIECE_S]))
y = coded_conv2d(x, w, mds, spec, executor=ex, assignment=counts)
r = ex.last_report
print(f"piece counts {counts} for speeds {speeds}; wall "
      f"{r.wall_s * 1e3:.1f} ms; assignment {r.assignment}; "
      f"max err {float(jnp.max(jnp.abs(y - y_ref))):.2e}")
ex.close()
