"""Coded dispatch on a real device mesh (DESIGN.md §13).

The same k-of-n coded GEMM, served by both implementations of the
``dist/backend.py`` execution seam:

* the **threaded pool** (`CodedExecutor`) — real k-th-arrival exit: the
  master decodes the moment k pieces land and cancels the rest;
* the **device mesh** (`MeshExecutor`) — each piece is one slice of the
  mesh's ``model`` axis and encode -> per-slice Pallas GEMM -> masked
  gather -> sharded decode compile into ONE shard_map program, built
  once per (scheme, shape, fault pattern) and replayed from cache.

Under SPMD nobody can cancel a slice, so the mesh models faults
algebraically: dead/straggling slices are masked out of the decode,
consuming exactly the subset the threaded master's k-th-arrival rule
picks under the same fault — which is why the two backends decode to
the SAME BYTES below, fault or no fault.  Swapping them is one
constructor argument on the serving engine.

This script forces an 8-way CPU device split so the mesh is genuine
SPMD on any machine.

Run: PYTHONPATH=src python examples/mesh_dispatch.py
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.coded_linear import coded_matmul
from repro.core.schemes import get_scheme
from repro.dist import (CodedExecutor, DeterministicDelay, FakeClock,
                        FaultPlan, MeshExecutor)
from repro.models.model import ModelConfig
from repro.serving import Engine, Request

N, K = 5, 3


def banner(s):
    print(f"\n=== {s} " + "=" * max(0, 66 - len(s)))


def main():
    print(f"devices: {jax.device_count()} x {jax.devices()[0].platform}")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(12, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    code = get_scheme("mds").make(N, K)

    banner(f"one coded GEMM, mds({N},{K}), worker 1 dead on both backends")
    with CodedExecutor(N, clock=FakeClock(),
                       delay_model=DeterministicDelay(1.0),
                       fault_plan=FaultPlan(dead=frozenset({1}))) as ex_t, \
            MeshExecutor(dead=(1,)) as ex_m:
        y_t = coded_matmul(x, w, code, executor=ex_t)
        y_m = coded_matmul(x, w, code, executor=ex_m)
        print(f"threads subset  : {list(ex_t.last_report.subset)} "
              f"(k-th ARRIVAL under the fault plan)")
        print(f"mesh subset     : {list(ex_m.last_report.subset)} "
              f"(modeled ahead of dispatch, dead slice masked)")
        same = (np.asarray(y_t).tobytes() == np.asarray(y_m).tobytes())
        print(f"decoded bitwise-identical: {same}")
        print(f"mesh wall: {ex_m.last_report.wall_s * 1e3:.2f} ms "
              f"(real device time; compile_count={ex_m.compile_count})")
        y_m2 = coded_matmul(x, w, code, executor=ex_m)
        print(f"second call replays the cached program "
              f"(compile_count={ex_m.compile_count}), bitwise: "
              f"{np.asarray(y_m2).tobytes() == np.asarray(y_m).tobytes()}")

    banner("the same serving engine on either backend: executor='mesh'")
    cfg = ModelConfig(name="mesh-demo", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab=32, gated=False,
                      dtype=jnp.float32, coded_n=4, coded_k=3,
                      coded_scheme="mds")
    reqs = [Request(i, ((np.arange(4) + 2 * i) % 32).astype(np.int32),
                    max_new=3) for i in range(2)]
    eng_m = Engine(cfg, seed=0, executor="mesh")
    out_m = eng_m.generate(reqs)
    with CodedExecutor(4, clock=FakeClock(),
                       delay_model=DeterministicDelay(1.0)) as ex:
        out_t = Engine(cfg, seed=0, executor=ex).generate(reqs)
    for a, b in zip(out_t, out_m):
        print(f"req {a.rid}: threads {a.tokens.tolist()} | "
              f"mesh {b.tokens.tolist()} | match {a.tokens.tolist() == b.tokens.tolist()}")
    print(f"mesh engine ran {eng_m.executor.run_count} coded GEMMs as "
          f"shard_map programs ({eng_m.executor.compile_count} compiles)")


if __name__ == "__main__":
    main()
